"""Event-engine edge cases: degenerate traces and racing mutations.

The discrete-event engine (core/events.py) must stay conservative
(arrived == completed + dropped) and crash-free under the inputs the
tick-parity suite never exercises: many events at one timestamp, empty
traces, bursts beyond fleet capacity, and policies that remove pods
while they are cold-starting or mid-batch.
"""
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import (ClusterSimulator, FnSpec, HybridAutoScaler,
                        Reconfigurator, SimConfig)
from repro.core.vgpu import PodAlloc

SPEC = FnSpec(ARCHS["olmo-1b"])


class StaticPolicy:
    """No-op policy: isolates engine mechanics from control feedback."""

    def tick(self, now, spec, observed_rps):
        return []


class ScriptedPolicy:
    """Replays (time, fn) mutation callbacks against the Reconfigurator
    — lets tests stage races the real policies only hit stochastically."""

    def __init__(self, recon, script):
        self.recon = recon
        self.script = sorted(script, key=lambda s: s[0])

    def tick(self, now, spec, observed_rps):
        while self.script and self.script[0][0] <= now:
            _, fn = self.script.pop(0)
            fn(self.recon, now)


def _static_sim(arr, n_pods=2, duration=20.0, **cfg_kw):
    recon = Reconfigurator(num_gpus=0, max_gpus=8)
    for _ in range(n_pods):
        recon.place_pod(PodAlloc(fn_id=SPEC.fn_id, sm=4, quota=0.5, batch=8),
                        None, now=0.0, cold_start_s=0.0)
    return ClusterSimulator(SPEC, StaticPolicy(), recon, arr,
                            SimConfig(duration_s=duration, **cfg_kw))


def test_simultaneous_arrivals_at_identical_timestamp():
    """50 requests at exactly t=1.0 plus duplicates later: every event
    shares a timestamp with another, exercising the heap tie-break."""
    arr = np.sort(np.concatenate([np.full(50, 1.0), np.full(30, 5.0),
                                  np.full(20, 5.0)]))
    res = _static_sim(arr).run()
    assert res.n_arrived == 100
    assert res.n_completed + res.n_dropped == res.n_arrived
    assert res.n_completed == 100  # capacity is ample; none age out


def test_arrival_tied_with_autoscale_and_completion():
    """Arrivals placed exactly on autoscale-timer timestamps (integer
    seconds) — the ARRIVAL < AUTOSCALE < DISPATCH priority must hold."""
    arr = np.arange(1.0, 15.0, 1.0)  # every arrival ties an autoscale event
    res = _static_sim(arr).run()
    assert res.n_completed == len(arr)


def test_zero_length_trace():
    res = _static_sim(np.array([]), duration=10.0).run()
    assert res.n_arrived == 0
    assert res.n_completed == 0 and res.n_dropped == 0
    # idle pods still accrue cost to the end of the run
    assert res.cost_usd > 0
    assert res.pcts["p50"] == float("inf")


def test_zero_length_trace_with_autoscaler():
    recon = Reconfigurator(num_gpus=0, max_gpus=8)
    pol = HybridAutoScaler(recon)
    pol.prewarm(SPEC, 5.0)
    res = ClusterSimulator(SPEC, pol, recon, np.array([]),
                           SimConfig(duration_s=10.0)).run()
    assert res.n_arrived == 0
    assert len(recon.pods_of(SPEC.fn_id)) >= 1  # never scales to zero


def test_burst_larger_than_fleet_capacity_sheds():
    """600 requests in one second against a single tiny pod that cannot
    absorb them before drop_after_s: conservation must hold and the
    overflow must be shed as drops, not lost."""
    rng = np.random.default_rng(5)
    arr = np.sort(rng.uniform(0.0, 1.0, size=600))
    recon = Reconfigurator(num_gpus=0, max_gpus=1)
    recon.place_pod(PodAlloc(fn_id=SPEC.fn_id, sm=1, quota=0.2, batch=4),
                    None, now=0.0, cold_start_s=0.0)
    res = ClusterSimulator(SPEC, StaticPolicy(), recon, arr,
                           SimConfig(duration_s=30.0, drop_after_s=5.0)).run()
    assert res.n_arrived == 600
    assert res.n_completed + res.n_dropped == 600
    assert res.n_dropped > 0
    # everything that did complete waited at most drop_after_s + service
    assert res.latencies.max() < 5.0 + 2.0


def test_scale_down_races_cold_start_in_flight():
    """A pod is removed while still cold-starting (its wake event is
    already queued): the engine must drop its runtime without crashing
    and keep serving through the surviving pod."""
    def add_cold(recon, now):
        recon.place_pod(PodAlloc(fn_id=SPEC.fn_id, sm=4, quota=0.5,
                                 batch=8, pod_id="cold-pod"),
                        None, now=now, cold_start_s=30.0)

    def remove_cold(recon, now):
        recon.remove_pod("cold-pod")
        recon.release_empty_gpus()

    recon = Reconfigurator(num_gpus=0, max_gpus=8)
    recon.place_pod(PodAlloc(fn_id=SPEC.fn_id, sm=4, quota=0.5, batch=8),
                    None, now=0.0, cold_start_s=0.0)
    pol = ScriptedPolicy(recon, [(2.0, add_cold), (5.0, remove_cold)])
    arr = np.sort(np.random.default_rng(3).uniform(0, 20.0, size=200))
    sim = ClusterSimulator(SPEC, pol, recon, arr, SimConfig(duration_s=20.0))
    res = sim.run()
    assert res.n_completed + res.n_dropped == res.n_arrived
    assert res.n_completed > 0
    assert "cold-pod" not in sim.runtimes
    # the cold pod was counted as a (cold) horizontal-up then removed
    assert res.cold_starts == 1
    assert res.action_counts["hup"] == 1 and res.action_counts["hdown"] == 1


def test_scale_down_races_busy_pod_completes_inflight():
    """Removing a pod mid-batch must deliver its in-flight requests at
    their fixed completion time instead of losing them."""
    def remove_first(recon, now):
        pods = recon.pods_of(SPEC.fn_id)
        if len(pods) > 1:
            recon.remove_pod(pods[0].pod_id)
            recon.release_empty_gpus()

    recon = Reconfigurator(num_gpus=0, max_gpus=8)
    for _ in range(2):
        recon.place_pod(PodAlloc(fn_id=SPEC.fn_id, sm=4, quota=0.5, batch=8),
                        None, now=0.0, cold_start_s=0.0)
    pol = ScriptedPolicy(recon, [(3.0, remove_first)])
    arr = np.sort(np.random.default_rng(4).uniform(0, 15.0, size=300))
    res = ClusterSimulator(SPEC, pol, recon, arr,
                           SimConfig(duration_s=15.0)).run()
    assert res.n_completed + res.n_dropped == res.n_arrived
    # completions were delivered, not dropped, when their pod vanished
    assert res.n_dropped == 0
    assert all(r is not None for r in res.latencies)


def test_trace_ending_before_duration_and_after():
    """Arrivals after duration_s (inside the drop-after grace window)
    are still injected; anything beyond the cutoff is shed as dropped,
    never silently lost."""
    arr = np.array([1.0, 2.0, 25.0])
    res = _static_sim(arr, duration=20.0, drop_after_s=10.0).run()
    assert res.n_arrived == 3
    assert res.n_completed + res.n_dropped == 3
    assert res.n_completed >= 2
